"""nn-block unit tests: rotary invariances, flash==direct, chunkwise
mLSTM == recurrent decode, mamba decode == scan, MoE dispatch exactness,
MLA absorbed decode == expanded form."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.nn import attention as at
from repro.nn import mamba as mamba_mod
from repro.nn import xlstm as xm
from repro.nn.moe import init_moe, apply_moe
from repro.nn.rotary import apply_rope, apply_mrope


def test_rope_preserves_norm_and_relative_angle(key):
    x = jax.random.normal(key, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def score(pq, pk):
        rq = apply_rope(q, jnp.array([[pq]], jnp.int32))
        rk = apply_rope(k, jnp.array([[pk]], jnp.int32))
        return float(jnp.sum(rq * rk))
    assert score(3, 5) == pytest.approx(score(10, 12), rel=1e-4)


def test_mrope_reduces_to_rope_on_text(key):
    """Equal position streams (text-only) => M-RoPE == RoPE."""
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    mpos = jnp.broadcast_to(pos[None], (3, 2, 8))
    y_rope = apply_rope(x, pos, theta=1e6)
    y_mrope = apply_mrope(x, mpos, sections=(2, 3, 3), theta=1e6)
    np.testing.assert_allclose(np.asarray(y_rope), np.asarray(y_mrope), atol=1e-5)


def test_flash_equals_direct_attention(key):
    import repro.nn.attention as amod

    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    p = at.init_gqa(key, cfg)
    x = jax.random.normal(key, (2, 4096, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(4096)[None], (2, 4096)).astype(jnp.int32)
    old = amod.FLASH_THRESHOLD
    try:
        amod.FLASH_THRESHOLD = 10**9
        y_direct = at.apply_gqa(p, x, cfg, positions=pos)
        amod.FLASH_THRESHOLD = 1024
        y_flash = at.apply_gqa(p, x, cfg, positions=pos)
    finally:
        amod.FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_direct),
                               atol=2e-5, rtol=2e-5)


def test_flash_backward_equals_direct(key):
    import repro.nn.attention as amod

    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    p = at.init_gqa(key, cfg)
    x = jax.random.normal(key, (1, 4096, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(4096)[None], (1, 4096)).astype(jnp.int32)

    def loss(p, thresh):
        amod.FLASH_THRESHOLD = thresh
        return jnp.sum(at.apply_gqa(p, x, cfg, positions=pos).astype(jnp.float32) ** 2)

    old = amod.FLASH_THRESHOLD
    try:
        g1 = jax.grad(loss)(p, 10**9)
        g2 = jax.grad(loss)(p, 1024)
    finally:
        amod.FLASH_THRESHOLD = old
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        scale = max(1.0, float(jnp.max(jnp.abs(a))))
        np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                                   atol=1e-5)


def test_mla_decode_equals_expanded(key):
    cfg = get_config("deepseek-v3-671b", reduced=True).replace(dtype="float32")
    p = at.init_mla(key, cfg)
    b, s = 2, 9
    x = jax.random.normal(key, (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    full = at.apply_mla(p, x, cfg, positions=pos)
    cache = jax.tree.map(lambda t: t.astype(jnp.float32), at.mla_init_cache(cfg, b, 16))
    _, cache = at.apply_mla_prefill(p, x[:, :8], cfg, positions=pos[:, :8], cache=cache)
    out, _ = at.apply_mla_decode(p, x[:, 8:9], cfg, cache=cache, cache_len=jnp.int32(8))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, 8]), atol=1e-4)


def test_mlstm_chunkwise_equals_recurrent(key):
    cfg = get_config("xlstm-1.3b", reduced=True).replace(dtype="float32")
    p = xm.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, 12, cfg.d_model))
    y_par = xm.apply_mlstm(p, x, cfg)
    st = xm.mlstm_init_state(cfg, 2)
    outs = []
    for t in range(12):
        yt, st = xm.apply_mlstm_decode(p, x[:, t:t+1], cfg, state=st)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-4)


def test_mlstm_chunk_boundaries_exact(key):
    import repro.nn.xlstm as xmod

    cfg = get_config("xlstm-1.3b", reduced=True).replace(dtype="float32")
    p = xm.init_mlstm(key, cfg)
    x = jax.random.normal(key, (1, 512, cfg.d_model))
    y_chunked = xm.apply_mlstm(p, x, cfg)           # 2 chunks of 256
    old = xmod.MLSTM_CHUNK
    try:
        xmod.MLSTM_CHUNK = 512
        y_single = xm.apply_mlstm(p, x, cfg)        # 1 chunk
    finally:
        xmod.MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_single), atol=1e-5)


def test_mamba_decode_equals_scan(key):
    cfg = get_config("jamba-v0.1-52b", reduced=True).replace(dtype="float32")
    p = mamba_mod.init_mamba(key, cfg)
    x = jax.random.normal(key, (2, 9, cfg.d_model))
    full, state = mamba_mod.apply_mamba(p, x[:, :8], cfg, return_state=True)
    y_dec, _ = mamba_mod.apply_mamba_decode(p, x[:, 8:9], cfg, state=state)
    ref = mamba_mod.apply_mamba(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(ref[:, 8]), atol=1e-5)


def test_moe_tokenwise_exactness(key):
    """Routing and expert compute are per-token: evaluating one token
    alone equals evaluating it in a batch (no cross-token leakage)."""
    cfg = get_config("deepseek-v3-671b", reduced=True).replace(
        dtype="float32", capacity_factor=8.0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 9, cfg.d_model))
    full, aux = apply_moe(p, x, cfg, capacity_factor=8.0)
    one, _ = apply_moe(p, x[:, 4:5], cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(one[:, 0]), np.asarray(full[:, 4]), atol=1e-5)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_masked_not_corrupt(key):
    """With capacity_factor ~0, most tokens drop: output must be the
    shared-expert path only (finite, no garbage from slot collisions)."""
    cfg = get_config("deepseek-v3-671b", reduced=True).replace(dtype="float32")
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, _ = apply_moe(p, x, cfg, capacity_factor=0.01)
    assert bool(jnp.all(jnp.isfinite(out)))
