"""Mixed-precision tests: PrecisionPolicy presets, dynamic loss scaling
(growth / backoff / overflow skip), property-based retraction
orthonormality across dtypes, sign-fix determinism, and the 30-step
bf16-mixed vs fp32 regression with checkpoint-restart bit-exactness of
the loss-scale state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import tree_equal
from repro.config import get_config
from repro.core import (
    POLICIES,
    PrecisionPolicy,
    all_finite,
    cast_tree,
    loss_scale_init,
    loss_scale_update,
    orthogonality_error,
    precision_policy,
    qr_retract,
    retract,
)
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig

# dtype-appropriate orthonormality tolerance: fp32 QR is ~1e-6; a
# bf16-stored factor re-rounds every entry to 2^-8 relative, so
# |U^T U - I| is bounded by ~sqrt(m) * bf16_eps
ORTHO_TOL = {jnp.float32: 5e-5, jnp.bfloat16: 0.08}


def _noisy_stiefel(key, m, k, noise):
    U0, _ = jnp.linalg.qr(jax.random.normal(key, (m, k)))
    return U0 + noise * jax.random.normal(jax.random.PRNGKey(1), (m, k))


# ------------------------------------------------------------ policies --

def test_policy_presets():
    assert POLICIES["fp32"].compute_dtype == "float32"
    assert not POLICIES["fp32"].loss_scaling
    assert POLICIES["bf16"].param_dtype == "bfloat16"
    assert POLICIES["bf16"].compute_dtype == "bfloat16"
    mixed = POLICIES["mixed"]
    assert mixed.param_dtype == "float32"       # fp32 master factors
    assert mixed.compute_dtype == "bfloat16"    # bf16 apply-time casts
    assert mixed.accum_dtype == "float32"
    assert mixed.loss_scaling


def test_precision_policy_resolution():
    assert precision_policy(None) is None
    assert precision_policy("mixed") is POLICIES["mixed"]
    pol = PrecisionPolicy(name="custom")
    assert precision_policy(pol) is pol
    with pytest.raises(ValueError):
        precision_policy("fp64")


def test_cast_tree_floats_only(key):
    tree = {"w": jnp.ones((2, 2)), "step": jnp.zeros((), jnp.int32)}
    out = cast_tree(tree, jnp.bfloat16)
    assert out["w"].dtype == jnp.bfloat16
    assert out["step"].dtype == jnp.int32


# ----------------------------------------------------------- loss scale --

def test_all_finite_detects_inf_nan(key):
    g = {"a": jnp.ones((3,)), "n": jnp.zeros((), jnp.int32)}
    assert bool(all_finite(g))
    assert not bool(all_finite({"a": jnp.array([1.0, jnp.inf])}))
    assert not bool(all_finite({"a": jnp.array([jnp.nan])}))


def test_loss_scale_growth_backoff_floor():
    pol = PrecisionPolicy(name="t", loss_scaling=True, init_scale=8.0,
                          growth_interval=2, min_scale=1.0, max_scale=32.0)
    ls = loss_scale_init(pol)
    ls = loss_scale_update(ls, jnp.bool_(True), pol)
    assert float(ls["scale"]) == 8.0 and int(ls["good_steps"]) == 1
    ls = loss_scale_update(ls, jnp.bool_(True), pol)   # interval hit: double
    assert float(ls["scale"]) == 16.0 and int(ls["good_steps"]) == 0
    ls = loss_scale_update(ls, jnp.bool_(False), pol)  # overflow: halve
    assert float(ls["scale"]) == 8.0
    assert int(ls["skipped"]) == 1 and int(ls["good_steps"]) == 0
    for _ in range(10):                                # floor at min_scale
        ls = loss_scale_update(ls, jnp.bool_(False), pol)
    assert float(ls["scale"]) == 1.0
    for _ in range(20):                                # cap at max_scale
        ls = loss_scale_update(ls, jnp.bool_(True), pol)
    assert float(ls["scale"]) == 32.0


# --------------------------------------- retraction properties by dtype --

@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(8, 96),
    kfrac=st.floats(0.1, 0.9),
    noise=st.floats(0.0, 0.08),
    seed=st.integers(0, 2**31 - 1),
)
def test_retraction_orthonormal_across_dtypes(m, kfrac, noise, seed):
    """U^T U ~ I to dtype-appropriate tolerance over random ranks/shapes
    in fp32 and bf16, for both retractions the optimizer dispatches."""
    k = max(1, int(kfrac * m))
    U0 = _noisy_stiefel(jax.random.PRNGKey(seed), m, k, noise)
    for dtype, tol in ORTHO_TOL.items():
        U = U0.astype(dtype)
        for method in ("qr", "cholesky_qr2"):
            R = retract(U, method)
            assert R.dtype == dtype
            assert float(orthogonality_error(R)) < tol, (m, k, method, dtype)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 64), k=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_sign_fix_determinism(m, k, seed):
    """The sign-fixed QR picks one deterministic branch: repeated calls
    are bit-identical, diag(Q^T U) >= 0 (the positive-diagonal-R branch),
    and flipping input column signs flips the output the same way."""
    k = min(k, m)
    U = _noisy_stiefel(jax.random.PRNGKey(seed), m, k, 0.05)
    R1 = qr_retract(U)
    R2 = qr_retract(U)
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R2))
    diag = np.diag(np.asarray(R1.T @ U))
    assert (diag >= -1e-5).all()
    flips = jnp.array([(-1.0) ** i for i in range(k)])
    np.testing.assert_allclose(np.asarray(qr_retract(U * flips)),
                               np.asarray(R1 * flips), atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-6), (jnp.bfloat16, 0.05)])
def test_retraction_idempotent_on_orthonormal(key, dtype, tol):
    """Retracting an already-orthonormal factor is the identity up to the
    storage dtype's rounding (sign-fix continuity, paper Eq. 5)."""
    U, _ = jnp.linalg.qr(jax.random.normal(key, (48, 12)))
    U = U.astype(dtype)
    for method in ("qr", "cholesky_qr2"):
        R = retract(U, method)
        assert float(jnp.max(jnp.abs(
            R.astype(jnp.float32) - U.astype(jnp.float32)))) < tol, method


# --------------------------------------------- training-level regression --

def _train(precision, steps=30, lr=3e-3, seed=0):
    cfg = get_config("smollm2-135m", reduced=True)
    opt = make_sct_optimizer(cfg, lr=lr, warmup=4, total_steps=steps,
                             precision=precision)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = opt.init(init_model(jax.random.PRNGKey(seed), cfg))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, seed=0)
    losses = []
    for i in range(steps):
        t, l = ds.batch(i, 8)
        state, m = step_fn(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        losses.append(float(m["loss"]))
    return state, losses


def test_mixed_tracks_fp32_30_steps():
    """30-step smollm2-135m: bf16-mixed loss tracks fp32 within
    tolerance, no spurious overflow skips, masters stay fp32, and the
    factors stay orthonormal to bf16-compute-appropriate tolerance."""
    state_m, loss_m = _train("mixed")
    state_f, loss_f = _train("fp32")
    assert np.isfinite(loss_m).all() and np.isfinite(loss_f).all()
    assert loss_m[-1] < loss_m[0] - 0.1          # actually learning
    assert abs(loss_m[-1] - loss_f[-1]) < 0.25   # tracks fp32
    assert np.max(np.abs(np.asarray(loss_m) - np.asarray(loss_f))) < 0.5
    assert int(state_m["loss_scale"]["skipped"]) == 0
    for leaf in jax.tree.leaves(state_m["params"]):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.float32     # fp32 master factors
    from repro.core.tree import max_orthogonality_error

    assert float(max_orthogonality_error(state_m["params"])) < 5e-5


def test_bf16_params_stay_bf16():
    state, losses = _train("bf16", steps=6)
    assert np.isfinite(losses).all()
    for leaf in jax.tree.leaves(state["params"]):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.bfloat16
    # bf16-stored factors after retraction: orthonormal to bf16 tolerance
    from repro.core.tree import max_orthogonality_error

    assert float(max_orthogonality_error(state["params"])) < ORTHO_TOL[jnp.bfloat16]


def test_overflow_skips_step_and_halves_scale(key):
    """Injected overflow: params and moments untouched, loss scale
    halves, the skip is counted, the global step still advances."""
    cfg = get_config("smollm2-135m", reduced=True)
    opt = make_sct_optimizer(cfg, lr=3e-3, precision="mixed")
    state = opt.init(init_model(jax.random.PRNGKey(0), cfg))
    scale0 = float(state["loss_scale"]["scale"])
    bad = jax.tree.map(lambda p: jnp.full(p.shape, jnp.inf, jnp.float32),
                       state["params"])
    new = opt.apply(state, bad)
    assert tree_equal(state["params"], new["params"])
    assert tree_equal(state["opt"]["mu"], new["opt"]["mu"])
    assert float(new["loss_scale"]["scale"]) == scale0 / 2
    assert int(new["loss_scale"]["skipped"]) == 1
    assert int(new["step"]) == 1
    # a finite step afterwards updates params again
    good = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32) * float(
        new["loss_scale"]["scale"]), state["params"])
    after = opt.apply(new, good)
    assert not tree_equal(new["params"], after["params"])
    assert int(after["loss_scale"]["skipped"]) == 1


def test_precision_mismatched_checkpoint_degrades_gracefully():
    """A state written under one precision policy must train under
    another: fp32 state + mixed optimizer falls back to the unscaled
    path (no KeyError); mixed state + legacy optimizer carries the
    loss_scale entry inertly and never applies still-scaled grads."""
    cfg = get_config("smollm2-135m", reduced=True)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)
    t, l = ds.batch(0, 4)
    batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
    params = init_model(jax.random.PRNGKey(0), cfg)

    opt_f = make_sct_optimizer(cfg, lr=1e-3, precision="fp32")
    opt_m = make_sct_optimizer(cfg, lr=1e-3, precision="mixed")
    opt_legacy = make_sct_optimizer(cfg, lr=1e-3)

    state_f = opt_f.init(params)                    # no loss_scale key
    s, m = jax.jit(make_train_step(cfg, opt_m))(state_f, batch)
    assert "loss_scale" not in s and np.isfinite(float(m["loss"]))

    state_m = opt_m.init(params)                    # has loss_scale
    s, m = jax.jit(make_train_step(cfg, opt_legacy))(state_m, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(s["loss_scale"]["scale"]) == float(state_m["loss_scale"]["scale"])
    # the applied update must be the unscaled one: equal to the pure
    # legacy step from the same params
    s_ref, _ = jax.jit(make_train_step(cfg, opt_legacy))(opt_legacy.init(params), batch)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(s["params"])[0]),
        np.asarray(jax.tree.leaves(s_ref["params"])[0]), rtol=0, atol=0)


def test_mixed_restart_restores_loss_scale_bit_exact(tmp_path):
    """Crash/restart under mixed precision: the full state — including a
    loss scale that grew mid-run — restores bit-exactly."""
    cfg = get_config("smollm2-135m", reduced=True)
    pol = PrecisionPolicy(name="mixed-fastgrow", compute_dtype="bfloat16",
                          loss_scaling=True, init_scale=2.0 ** 10,
                          growth_interval=3)
    total = 12

    def make_loop(d, failure_hook=None):
        opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=total,
                                 precision=pol)
        step_fn = jax.jit(make_train_step(cfg, opt))
        ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

        def batches(start):
            step = start
            while True:
                t, l = ds.batch(step, 4)
                yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
                step += 1

        return TrainLoop(
            step_fn=step_fn,
            batch_iter_factory=batches,
            ckpt_dir=str(d),
            cfg=TrainLoopConfig(total_steps=total, checkpoint_every=4,
                                max_restarts=3),
            init_state_fn=lambda: opt.init(init_model(jax.random.PRNGKey(0), cfg)),
            failure_hook=failure_hook,
        )

    straight = make_loop(tmp_path / "a").run()
    # the scale must actually have moved (growth_interval=3 over 12 steps)
    assert float(straight["loss_scale"]["scale"]) > 2.0 ** 10

    crashed = {"done": False}

    def bomb(step):
        if step == 8 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    loop = make_loop(tmp_path / "b", failure_hook=bomb)
    resumed = loop.run()
    assert loop.restarts == 1
    assert tree_equal(straight, resumed)        # full state incl. loss_scale
    assert (np.asarray(straight["loss_scale"]["scale"]).tobytes()
            == np.asarray(resumed["loss_scale"]["scale"]).tobytes())
