"""Pallas kernel tests: shape/dtype sweeps against the jnp oracles
(interpret mode on CPU), plus gradient checks through custom_vjp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import spectral_matmul
from repro.kernels.ref import spectral_matmul_ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_ref import flash_attention_ref


SPECTRAL_SHAPES = [
    (64, 64, 96, 16),
    (128, 256, 512, 32),
    (100, 300, 700, 64),    # unaligned -> exercises padding
    (32, 128, 128, 128),    # k == m
    (256, 512, 384, 8),     # tiny rank
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SPECTRAL_SHAPES)
def test_spectral_matmul_vs_oracle(shape, dtype, key):
    M, m, n, k = shape
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m), dtype)
    U = (jax.random.normal(ks[1], (m, k)) / np.sqrt(m)).astype(jnp.float32)
    s = jax.random.uniform(ks[2], (k,))
    V = (jax.random.normal(ks[3], (n, k)) / np.sqrt(n)).astype(jnp.float32)
    y = spectral_matmul(x, U, s, V)
    yr = spectral_matmul_ref(x, U, s, V)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_spectral_matmul_batched_leading_dims(key):
    x = jax.random.normal(key, (2, 3, 64))
    U = jax.random.normal(key, (64, 8)) / 8
    s = jnp.ones((8,))
    V = jax.random.normal(key, (96, 8)) / 10
    y = spectral_matmul(x, U, s, V)
    assert y.shape == (2, 3, 96)
    yr = spectral_matmul_ref(x.reshape(-1, 64), U, s, V).reshape(2, 3, 96)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-5, atol=5e-5)


def test_spectral_matmul_gradients_match_oracle(key):
    M, m, n, k = 64, 128, 160, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m))
    U = jax.random.normal(ks[1], (m, k)) / np.sqrt(m)
    s = jax.random.uniform(ks[2], (k,))
    V = jax.random.normal(ks[3], (n, k)) / np.sqrt(n)

    f = lambda *a: jnp.sum(spectral_matmul(*a) ** 2)
    fr = lambda *a: jnp.sum(spectral_matmul_ref(*a) ** 2)
    g = jax.grad(f, argnums=(0, 1, 2, 3))(x, U, s, V)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3))(x, U, s, V)
    for a, b in zip(g, gr):
        scale = max(1.0, float(jnp.max(jnp.abs(b))))
        np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


FLASH_SHAPES = [
    (2, 512, 64, True),
    (4, 1024, 64, True),
    (2, 2048, 128, True),
    (3, 512, 64, False),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,s,d,causal", FLASH_SHAPES)
def test_flash_attention_vs_oracle(B, s, d, causal, dtype, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, s, d), dtype)
    k = jax.random.normal(ks[1], (B, s, d), dtype)
    v = jax.random.normal(ks[2], (B, s, d), dtype)
    y = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    yr = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_jnp_flash_fallback_matches_kernel_semantics(key):
    """The jnp fallback the dry-run partitions and the Pallas kernel the
    TPU deploys must agree (same chunking, same math)."""
    from repro.nn.attention import _flash

    B, s, d = 2, 2048, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, s, d))
    k = jax.random.normal(ks[1], (B, s, d))
    v = jax.random.normal(ks[2], (B, s, d))
    # grouped form: (b, s, g=B-heads folded differently) — use g=1, r=1
    qg = q[:, :, None, None, :]
    kg = k[:, :, None, :]
    vg = v[:, :, None, :]
    y_fallback = _flash(qg, kg, vg, True)[:, :, 0, 0, :]
    y_kernel = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_fallback), np.asarray(y_kernel),
                               rtol=2e-5, atol=2e-5)
