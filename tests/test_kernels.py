"""Pallas kernel tests: shape/dtype sweeps against the jnp oracles via
the kernels/testing.py differential harness, gradient checks through
custom_vjp, and the fused-int8 kernel's equivalence + no-grad contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import spectral_matmul, spectral_matmul_q8
from repro.kernels.ref import spectral_matmul_ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_ref import flash_attention_ref
from repro.kernels.testing import (
    SCALE_PROFILES,
    Tol,
    assert_kernel_matches,
    scale_profile,
    tolerance_for,
)
from repro.serving.quantize import dequantize_int8, quantize_int8


SPECTRAL_SHAPES = [
    (64, 64, 96, 16),
    (128, 256, 512, 32),
    (100, 300, 700, 64),    # unaligned -> exercises padding
    (32, 128, 128, 128),    # k == m
    (256, 512, 384, 8),     # tiny rank
]


def _spectral_args(key, M, m, n, k, dtype):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m), dtype)
    U = (jax.random.normal(ks[1], (m, k)) / np.sqrt(m)).astype(jnp.float32)
    s = jax.random.uniform(ks[2], (k,))
    V = (jax.random.normal(ks[3], (n, k)) / np.sqrt(n)).astype(jnp.float32)
    return x, U, s, V


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SPECTRAL_SHAPES)
def test_spectral_matmul_vs_oracle(shape, dtype, key):
    args = _spectral_args(key, *shape, dtype)
    assert_kernel_matches(spectral_matmul, spectral_matmul_ref, args,
                          dtype=dtype)


def test_spectral_matmul_batched_leading_dims(key):
    x = jax.random.normal(key, (2, 3, 64))
    U = jax.random.normal(key, (64, 8)) / 8
    s = jnp.ones((8,))
    V = jax.random.normal(key, (96, 8)) / 10
    y = spectral_matmul(x, U, s, V)
    assert y.shape == (2, 3, 96)
    yr = spectral_matmul_ref(x.reshape(-1, 64), U, s, V).reshape(2, 3, 96)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-5, atol=5e-5)


def test_spectral_matmul_gradients_match_oracle(key):
    x, U, s, V = _spectral_args(key, 64, 128, 160, 16, jnp.float32)

    f = lambda *a: jnp.sum(spectral_matmul(*a) ** 2)
    fr = lambda *a: jnp.sum(spectral_matmul_ref(*a) ** 2)
    g = jax.grad(f, argnums=(0, 1, 2, 3))(x, U, s, V)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3))(x, U, s, V)
    for a, b in zip(g, gr):
        scale = max(1.0, float(jnp.max(jnp.abs(b))))
        np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- fused int8 --

def _q8_ref(x, U_qt, s, V_qt):
    """The dequantize-then-matmul chain the fused kernel replaces —
    same quantized factors, so only the kernel's scale reassociation
    (fused k-length gain vs two factor-shaped dequants) differs."""
    return spectral_matmul_ref(x, dequantize_int8(U_qt), s,
                               dequantize_int8(V_qt))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("profile", SCALE_PROFILES)
def test_spectral_matmul_q8_matches_dequant_chain(profile, dtype, key):
    """Fused kernel == dequantize-then-matmul within the per-dtype rung,
    under per-channel scale ratios up to eight decades ('extreme')."""
    M, m, n, k = 100, 300, 700, 64
    x, U, s, V = _spectral_args(key, M, m, n, k, dtype)
    mags = scale_profile(profile, k)
    U_qt = quantize_int8(U * mags[None, :])   # per-column amax -> scale ratio
    V_qt = quantize_int8(V)
    assert_kernel_matches(spectral_matmul_q8, _q8_ref, (x, U_qt, s, V_qt),
                          dtype=dtype, label=f"q8:{profile}")


def test_spectral_matmul_q8_batched_leading_dims(key):
    x, U, s, V = _spectral_args(key, 6, 64, 96, 8, jnp.float32)
    U_qt, V_qt = quantize_int8(U), quantize_int8(V)
    y = spectral_matmul_q8(x.reshape(2, 3, 64), U_qt, s, V_qt)
    assert y.shape == (2, 3, 96)
    yr = _q8_ref(x, U_qt, s, V_qt).reshape(2, 3, 96)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-5, atol=5e-5)


def test_spectral_matmul_q8_has_no_gradient(key):
    """Serving-only contract: int8 factors carry no gradient, and
    differentiating through the op must raise — not silently return a
    wrong cotangent (jit'd primal use stays fine)."""
    x, U, s, V = _spectral_args(key, 16, 64, 96, 8, jnp.float32)
    U_qt, V_qt = quantize_int8(U), quantize_int8(V)
    jax.jit(spectral_matmul_q8)(x, U_qt, s, V_qt)   # primal under jit: ok
    with pytest.raises(TypeError, match="serving-only"):
        jax.grad(lambda a: spectral_matmul_q8(a, U_qt, s, V_qt).sum())(x)


def test_tolerance_ladder_rejects_unknown_dtype():
    with pytest.raises(KeyError):
        tolerance_for(jnp.int8)
    assert tolerance_for(jnp.float32) == Tol(5e-5, 5e-5)


# ------------------------------------------------------------- flash --

FLASH_SHAPES = [
    (2, 512, 64, True),
    (4, 1024, 64, True),
    (2, 2048, 128, True),
    (3, 512, 64, False),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,s,d,causal", FLASH_SHAPES)
def test_flash_attention_vs_oracle(B, s, d, causal, dtype, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, s, d), dtype)
    k = jax.random.normal(ks[1], (B, s, d), dtype)
    v = jax.random.normal(ks[2], (B, s, d), dtype)
    tol = Tol(2e-5, 2e-5) if dtype == jnp.float32 else Tol(3e-2, 3e-2)
    assert_kernel_matches(
        lambda *a: flash_attention_pallas(*a, causal=causal, interpret=True),
        lambda *a: flash_attention_ref(*a, causal=causal),
        (q, k, v), tol=tol, label=f"flash causal={causal}")


def test_jnp_flash_fallback_matches_kernel_semantics(key):
    """The jnp fallback the dry-run partitions and the Pallas kernel the
    TPU deploys must agree (same chunking, same math)."""
    from repro.nn.attention import _flash

    B, s, d = 2, 2048, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, s, d))
    k = jax.random.normal(ks[1], (B, s, d))
    v = jax.random.normal(ks[2], (B, s, d))
    # grouped form: (b, s, g=B-heads folded differently) — use g=1, r=1
    qg = q[:, :, None, None, :]
    kg = k[:, :, None, :]
    vg = v[:, :, None, :]
    y_fallback = _flash(qg, kg, vg, True)[:, :, 0, 0, :]
    y_kernel = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_fallback), np.asarray(y_kernel),
                               rtol=2e-5, atol=2e-5)
