"""Long-context streaming subsystem: pinned attention sinks,
sliding-window page eviction, cold-KV int8 demotion.

The contract under test: inside the identity horizon
((sink + window) * page_size tokens) streaming serving is
token-for-token identical to the full-cache engine; beyond it, a
session decodes arbitrarily far past the pool's nominal capacity on an
O(sink + window) resident page budget, deterministically, with sinks
never evicted and the ledger (evictions / demotions / cold bytes)
reproducible run-to-run."""
import numpy as np
import pytest

from repro.config import get_config
from repro.launch.serve import static_greedy_reference
from repro.models.model import init_model
from repro.serving import (
    PagedCacheConfig,
    PagePool,
    Request,
    StreamingConfig,
    identity_horizon,
    resident_cap,
    windowed_reservation,
)
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.streaming import validate_geometry


# ======================================================================
# PagePool pin API (the sink guard)
# ======================================================================

def test_pagepool_pin_is_release_floor():
    """A pin is a refcount floor: release that would drop below it
    raises loudly (the sink-eviction guard), while extra references
    above the floor come and go freely."""
    pool = PagePool(4)
    a = pool.alloc(2)
    pool.pin([a[0]])
    assert pool.pin_count(a[0]) == 1
    with pytest.raises(RuntimeError, match="pinned"):
        pool.release([a[0]])                 # would orphan the pin
    assert pool.refcount(a[0]) == 1          # failed release mutated nothing
    pool.share([a[0]])                       # a second holder above the floor
    pool.release([a[0]])                     # ... may release normally
    assert pool.refcount(a[0]) == 1
    pool.unpin([a[0]])
    pool.release([a[0]])                     # floor gone: normal release
    assert pool.allocated_count == 1
    with pytest.raises(RuntimeError):
        pool.unpin([a[1]])                   # unpin of unpinned page


def test_pagepool_counted_pins_stack():
    """Two sequences sharing a sink page each pin it; one unpin leaves
    the other's floor intact."""
    pool = PagePool(2)
    (p,) = pool.alloc(1)
    pool.pin([p])
    pool.share([p])
    pool.pin([p])
    assert pool.pin_count(p) == 2
    pool.unpin([p])
    with pytest.raises(RuntimeError, match="pinned"):
        pool.release([p] * 2)                # second release breaches the floor
    assert pool.refcount(p) == 2             # atomic: nothing released
    pool.unpin([p])
    pool.release([p] * 2)
    assert pool.allocated_count == 0


# ======================================================================
# Policy geometry
# ======================================================================

def test_streaming_config_validation():
    with pytest.raises(ValueError):
        StreamingConfig(sink_pages=0)
    with pytest.raises(ValueError):
        StreamingConfig(window_pages=0)
    with pytest.raises(ValueError):
        StreamingConfig(cold_kv="fp4")
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_pages_per_seq=3)
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        validate_geometry(StreamingConfig(sink_pages=1, window_pages=3), pcfg)


def test_windowed_reservation_caps_long_requests():
    cfg = StreamingConfig(sink_pages=1, window_pages=2)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_pages_per_seq=4)
    assert resident_cap(cfg) == 4
    assert windowed_reservation(cfg, pcfg, 100_000) == 4     # O(sink+window)
    assert windowed_reservation(cfg, pcfg, 7) == 2           # short stays short
    assert identity_horizon(cfg, pcfg) == 12


# ======================================================================
# Scheduler: windowed admission, eviction, pinned sinks
# ======================================================================

def test_scheduler_streams_8x_pool_capacity():
    """A session 8x the pool's token capacity admits (reservation is the
    windowed cap, not the footprint) and decodes to completion with at
    most sink+window+1 pages resident; the sink page is pinned, never
    evicted, and everything releases cleanly at the end."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2)
    sched = ContinuousBatchingScheduler(pcfg, streaming=scfg)
    total = 8 * pcfg.num_pages * pcfg.page_size          # 256 tokens
    sched.submit(Request(rid=0, prompt=np.zeros(4, np.int32),
                         max_new_tokens=total - 4))
    (seq,) = sched.admit()
    assert seq.reserved_pages == resident_cap(scfg)
    seq.prefill_pos = 4
    sched.finish_prefill(seq.slot)
    sched.on_prefill_token(seq.slot, 1)
    sink = seq.pages[0]
    assert seq.pinned == [sink] and sched.pool.pin_count(sink) == 1
    done = None
    while done is None:
        sched.stream_maintain(seq.slot, 1)
        sched.ensure_append_capacity()
        assert len(seq.pages) <= resident_cap(scfg)
        assert seq.pages[0] == sink                      # sink never evicted
        sched.check_invariants()
        done = sched.on_token(seq.slot, 1)
    assert done.status == "finished"
    assert len(done.generated) == total - 4
    assert sched.stream_evictions >= (total // pcfg.page_size
                                      - resident_cap(scfg))
    assert sched.pool.allocated_count == 0               # pins released too
    assert sched.pool.pin_count(sink) == 0


def test_scheduler_concurrent_streams_share_small_pool():
    """Two windowed sessions whose combined *logical* footprint is many
    times the pool coexist: reservations are per-window, so admission
    does not serialize them."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=2,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2)
    sched = ContinuousBatchingScheduler(pcfg, streaming=scfg)
    for rid in range(2):
        sched.submit(Request(rid=rid, prompt=np.zeros(4, np.int32),
                             max_new_tokens=96))
    seqs = sched.admit()
    assert len(seqs) == 2                                # both admitted at once
    for seq in seqs:
        seq.prefill_pos = 4
        sched.finish_prefill(seq.slot)
        sched.on_prefill_token(seq.slot, 1)
    finished = 0
    while finished < 2:
        for slot in list(sched.active):
            sched.stream_maintain(slot, 1)
        sched.ensure_append_capacity()
        sched.check_invariants()
        for slot in list(sched.active):
            if sched.on_token(slot, 1) is not None:
                finished += 1
    assert sched.pool.allocated_count == 0


# ======================================================================
# Engine: identity inside the horizon (GQA + MLA, both cold modes)
# ======================================================================

@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b"])
@pytest.mark.parametrize("cold_kv", ["none", "int8"])
def test_streaming_under_horizon_matches_oracle(arch, cold_kv, key):
    """Requests that finish inside (sink+window)*page_size tokens see no
    eviction and no demotion candidates, so streaming greedy output is
    token-for-token the static oracle's — for GQA and absorbed MLA,
    with and without the cold-int8 machinery armed. (capacity_factor
    pinned high: MoE token identity holds in the capacity-unbound
    regime only — see docs/serving.md.)"""
    cfg = get_config(arch, reduced=True).replace(dtype="float32",
                                                 capacity_factor=8.0)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2, cold_kv=cold_kv)
    horizon = identity_horizon(scfg, pcfg)               # 12 tokens
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(n,))
                    .astype(np.int32), max_new_tokens=g, arrival=a)
            for i, (n, g, a) in enumerate([(6, 6, 0), (5, 7, 1), (8, 4, 2)])]
    assert all(r.max_total_len <= horizon for r in reqs)
    engine = ServingEngine(cfg, params, pcfg, streaming=scfg)
    out = engine.run(reqs)
    engine.sched.check_invariants()
    assert engine.sched.pool.allocated_count == 0
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt,
                                      r.max_new_tokens, pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref,
                                      err_msg=f"request {r.rid}")


# ======================================================================
# Engine: sessions far past pool capacity + deterministic ledger
# ======================================================================

def _long_session_engine(cfg, params, pcfg, scfg, prompt, gen):
    engine = ServingEngine(cfg, params, pcfg, streaming=scfg,
                           chunked_prefill=True)
    out = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=gen)])
    engine.sched.check_invariants()
    return out[0], engine.stats()


@pytest.mark.parametrize("cold_kv", ["none", "int8"])
def test_streaming_session_8x_pool_capacity(cold_kv, key):
    """End-to-end: one session decodes to 8x the pool's non-streaming
    token capacity without OOM; rerunning the identical session
    reproduces the tokens and the eviction/demotion ledger exactly
    (beyond the horizon output diverges from the full cache, but
    deterministically)."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2, cold_kv=cold_kv)
    capacity = pcfg.num_pages * pcfg.page_size           # 32 tokens
    total = 8 * capacity                                 # 256 tokens
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    out_a, st_a = _long_session_engine(cfg, params, pcfg, scfg,
                                       prompt, total - len(prompt))
    assert len(out_a) == total - len(prompt)
    assert st_a["stream_evictions"] > 0
    assert st_a["peak_pages"] <= resident_cap(scfg)
    if cold_kv == "int8":
        assert st_a["stream_demotions"] > 0
        assert st_a["cold_page_bytes"] > 0
    else:
        assert st_a["stream_demotions"] == 0
    out_b, st_b = _long_session_engine(cfg, params, pcfg, scfg,
                                       prompt, total - len(prompt))
    np.testing.assert_array_equal(out_a, out_b)
    for k in ("stream_evictions", "stream_demotions", "cold_page_bytes",
              "peak_pages", "generated_tokens"):
        assert st_a[k] == st_b[k], k


def test_streaming_cold_kernel_matches_gather(key, monkeypatch):
    """The cold Pallas kernels and the jnp dequant-gather branch are two
    implementations of the same attention: an int8 streaming session far
    past the horizon — cold flags actually set — emits identical tokens
    and an identical demotion ledger under SCT_PAGED_KERNEL=1 and =0."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2, cold_kv="int8")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    outs, stats = {}, {}
    for gate in ("1", "0"):
        monkeypatch.setenv("SCT_PAGED_KERNEL", gate)
        outs[gate], stats[gate] = _long_session_engine(
            cfg, params, pcfg, scfg, prompt, 72)
    assert stats["1"]["stream_demotions"] > 0
    np.testing.assert_array_equal(outs["1"], outs["0"])
    for k in ("stream_evictions", "stream_demotions", "cold_page_bytes"):
        assert stats["1"][k] == stats["0"][k], k


# ======================================================================
# Composition: streaming x prefix cache (shared sinks stay shared)
# ======================================================================

def test_streaming_prefix_cache_warm_shared_sinks(key):
    """A cached shared prefix inside the sink region is mapped with a
    refcount bump — not copied — and stays warm across run() calls;
    under-horizon outputs remain oracle-exact and every pin unwinds."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    scfg = StreamingConfig(sink_pages=1, window_pages=2)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    engine = ServingEngine(cfg, params, pcfg, streaming=scfg,
                           prefix_cache=True)
    out1 = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=3)])
    shared_before = engine.stats()["prefix_shared_tokens"]
    out2 = engine.run([Request(rid=1, prompt=prompt, max_new_tokens=3)])
    engine.sched.check_invariants()
    assert engine.stats()["prefix_shared_tokens"] > shared_before
    np.testing.assert_array_equal(out1[0], out2[1])
    ref = static_greedy_reference(cfg, params, prompt, 3, pcfg.max_seq)
    np.testing.assert_array_equal(out1[0], ref)
    # retained index pages carry the index's reference only — every
    # per-sequence pin was undone at eviction
    for p in engine.sched.prefix_cache.pages:
        assert engine.sched.pool.refcount(p) == 1
        assert engine.sched.pool.pin_count(p) == 0
    assert engine.sched.pool.allocated_count == \
        len(engine.sched.prefix_cache.pages)


# ======================================================================
# Spec-level gates
# ======================================================================

def test_streaming_spec_gates():
    from repro.api import ServeSpec, StreamingSpec

    sv = ServeSpec(mode="paged", page_size=4, num_pages=32, slots=2,
                   pages_per_seq=8,
                   streaming=StreamingSpec(window_pages=2))
    assert sv.streaming.enabled
    assert sv.streaming.config() == StreamingConfig(sink_pages=1,
                                                    window_pages=2)
    assert StreamingSpec().config() is None              # disabled default
    with pytest.raises(ValueError, match="speculative"):
        sv.replace(speculative_rank="8")
    with pytest.raises(ValueError, match="disaggregat"):
        sv.replace(disaggregate=True)
    with pytest.raises(ValueError):
        sv.replace(mode="static")
    with pytest.raises(ValueError, match="pages_per_seq"):
        sv.replace(streaming=StreamingSpec(window_pages=16))
    with pytest.raises(ValueError, match="cold_kv"):
        StreamingSpec(cold_kv="int8")                    # needs a window


def test_streaming_engine_rejects_recurrent_family(key):
    cfg = get_config("xlstm-1.3b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=12, max_slots=2,
                            max_pages_per_seq=4)
    with pytest.raises(NotImplementedError):
        ServingEngine(cfg, params, pcfg,
                      streaming=StreamingConfig(sink_pages=1, window_pages=2))
