"""Minimal deterministic stand-in for the subset of hypothesis used by
the property tests (``given``/``settings``/``strategies.integers``/
``strategies.floats``), so the tier-1 suite collects and runs in
environments where hypothesis is not installed (the paper-repro
container bakes in only the jax toolchain).

Real hypothesis — installed via ``pip install -e .[test]`` / CI — is
always preferred; test modules fall back to this module only on
``ModuleNotFoundError``. The fallback draws a fixed number of
pseudo-random examples from a seeded RNG, so runs are reproducible but
without shrinking or database replay.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


class st:  # noqa: N801 — mirrors `from hypothesis import strategies as st`
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    booleans = staticmethod(_booleans)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    """Decorator-factory: records max_examples on the wrapped test."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    """Keyword-strategies form only (the form the suite uses). Runs the
    test once per drawn example; remaining parameters stay visible to
    pytest for fixture injection."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 10)
            for i in range(n):
                # string seed: hashed with sha512, stable across processes
                # (a tuple seed would go through hash() and vary with
                # PYTHONHASHSEED)
                rng = random.Random(f"{fn.__name__}:{i}")
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide strategy-bound params from pytest so it does not look for
        # same-named fixtures
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco
